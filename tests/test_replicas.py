"""ReplicaSet: hedged dispatch, failover, resurrection.

The deterministic half drives a ReplicaSet of fake in-process workers
(controllable latency/failure per replica), pinning the exact hedging
contract: hedge fires after the delay, first result wins, the loser is
cancelled, failures roll to the next replica, and the caller sees the
typed WorkerDied only when every replica is gone.  The integration half
runs the process transport: SIGKILL one replica mid-stream (zero client
errors, the slot respawns) and SIGSTOP one replica (the hedge bounds the
stall instead of inheriting it).
"""
import os
import signal
import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.cluster import ClusterService, WorkerDied
from repro.cluster.partition import split_doc_ranges
from repro.cluster.workers.replica import ReplicaSet
from repro.core import KeywordSearchEngine
from repro.data import generate_discogs_tree


@pytest.fixture(scope="module")
def corpus():
    return generate_discogs_tree(n_releases=12, seed=5)


@pytest.fixture(scope="module")
def spec(corpus):
    return split_doc_ranges(corpus, 1)[0]


class FakeWorker:
    """Worker-protocol stub with a scriptable submit future per replica.

    ``delay=None`` parks the future forever (a stalled replica);
    ``fail=`` completes it with that exception; otherwise a timer thread
    resolves it with ``(slot, keywords)`` after ``delay`` seconds.
    """

    def __init__(self, slot, on_death, delay=0.0, fail=None):
        self.slot = slot
        self.on_death = on_death
        self.delay = delay
        self.fail = fail
        self.calls = 0
        self.closed = False
        self.pending: list[Future] = []

    def submit(self, keywords, semantics):
        self.calls += 1
        fut: Future = Future()
        self.pending.append(fut)

        def finish():
            if self.fail is not None:
                if not fut.cancelled():
                    fut.set_exception(self.fail)
            elif not fut.cancelled():
                fut.set_result((self.slot, tuple(keywords)))

        if self.delay is None:
            return fut  # parked forever: the stall case
        if self.delay == 0:
            finish()
        else:
            t = threading.Timer(self.delay, finish)
            t.daemon = True
            t.start()
        return fut

    def doc_stats(self, kw_ids):
        return self.submit([str(k) for k in kw_ids], "stats")

    def stats(self):
        from repro.core.engine import QueryStats

        return QueryStats(data={"queries": self.calls})

    def drain(self, timeout=30.0):
        pass

    def close(self, timeout=30.0):
        self.closed = True

    def die(self):
        """Simulate the reader thread noticing the transport died."""
        self.on_death(self)


def make_set(spec, behaviours, **kw):
    """ReplicaSet over FakeWorkers; behaviours[slot] = dict for FakeWorker."""
    built: list[FakeWorker] = []

    def factory(slot, on_death):
        w = FakeWorker(slot, on_death, **behaviours[slot % len(behaviours)])
        built.append(w)
        return w

    rs = ReplicaSet(spec, factory, len(behaviours), **kw)
    return rs, built


# --------------------------------------------------------------------------- #
# Deterministic hedging
# --------------------------------------------------------------------------- #


def test_hedge_fires_on_stalled_replica_and_cancels_loser(spec):
    # replica 0 stalls forever; replica 1 answers instantly
    rs, built = make_set(spec, [{"delay": None}, {"delay": 0.0}],
                         hedge_ms=20.0)
    try:
        slot, kws = rs.submit(["vinyl"], "slca").result(timeout=10)
        assert slot == 1 and kws == ("vinyl",)
        s = rs.stats().data
        assert s["hedges_fired"] == 1 and s["hedge_wins"] == 1
        assert s["failovers"] == 0
        # the stalled loser's future was cancelled, not abandoned
        assert built[0].pending[0].cancelled()
    finally:
        rs.close()


def test_fast_primary_wins_without_hedge(spec):
    rs, built = make_set(spec, [{"delay": 0.0}, {"delay": 0.0}],
                         hedge_ms=10_000.0)
    try:
        rs.submit(["a"], "slca").result(timeout=10)
        s = rs.stats().data
        assert s["hedges_fired"] == 0 and s["hedge_wins"] == 0
        # round-robin: the second call starts on the other replica
        rs.submit(["b"], "slca").result(timeout=10)
        assert built[0].calls == 1 and built[1].calls == 1
    finally:
        rs.close()


def test_hedge_loser_result_is_dropped(spec):
    # both answer, primary slower: the hedge wins, the late primary result
    # must land on a cancelled future (dropped on delivery)
    rs, built = make_set(spec, [{"delay": 0.2}, {"delay": 0.0}],
                         hedge_ms=10.0)
    try:
        slot, _ = rs.submit(["x"], "slca").result(timeout=10)
        assert slot == 1
        time.sleep(0.3)  # let the loser's timer deliver into the dead future
        assert built[0].pending[0].cancelled()
        assert rs.stats().data["hedge_wins"] == 1
    finally:
        rs.close()


def test_hedge_disabled_with_inf(spec):
    rs, _ = make_set(spec, [{"delay": 0.05}, {"delay": 0.0}],
                     hedge_ms=float("inf"))
    try:
        slot, _ = rs.submit(["x"], "slca").result(timeout=10)
        assert slot == 0  # no hedge: the slow primary still answers
        assert rs.stats().data["hedges_fired"] == 0
    finally:
        rs.close()


def test_adaptive_hedge_delay_tracks_percentile(spec):
    rs, _ = make_set(spec, [{"delay": 0.0}, {"delay": 0.0}])
    try:
        assert rs._hedge_delay_s() == pytest.approx(0.05)  # cold default
        for ms in [1.0] * 100:
            rs._record_latency(ms)
        # p95 of 1ms wins clamps to the floor
        assert rs._hedge_delay_s() == pytest.approx(0.002)
        for ms in [40.0] * 100:
            rs._record_latency(ms)
        assert rs._hedge_delay_s() >= 0.02
    finally:
        rs.close()


def test_single_replica_never_hedges(spec):
    rs, _ = make_set(spec, [{"delay": 0.0}], hedge_ms=0.0)
    try:
        assert rs._hedge_delay_s() is None
        rs.submit(["x"], "slca").result(timeout=10)
        assert rs.stats().data["hedges_fired"] == 0
    finally:
        rs.close()


# --------------------------------------------------------------------------- #
# Failover + death
# --------------------------------------------------------------------------- #


def test_failed_attempt_rolls_to_next_replica(spec):
    rs, _ = make_set(
        spec,
        [{"fail": WorkerDied(0, "shot")}, {"delay": 0.0}],
        hedge_ms=10_000.0,  # hedging off: pure failover path
    )
    try:
        slot, _ = rs.submit(["x"], "slca").result(timeout=10)
        assert slot == 1
        s = rs.stats().data
        assert s["failovers"] == 1 and s["hedge_wins"] == 0
    finally:
        rs.close()


def test_all_replicas_failing_surfaces_typed(spec):
    rs, _ = make_set(
        spec,
        [{"fail": WorkerDied(0, "a")}, {"fail": WorkerDied(0, "b")}],
        hedge_ms=10_000.0,
    )
    try:
        with pytest.raises(WorkerDied):
            rs.submit(["x"], "slca").result(timeout=10)
    finally:
        rs.close()


def test_replica_death_marks_slot_and_respawns(spec):
    rs, built = make_set(
        spec, [{"delay": 0.0}, {"delay": 0.0}],
        hedge_ms=10_000.0, respawn_backoff=0.01,
    )
    try:
        built[0].die()
        deadline = time.time() + 10
        while rs.stats().data.get("replica_respawns", 0) < 1:
            assert time.time() < deadline, rs.stats().data
            time.sleep(0.02)
        s = rs.stats().data
        assert s["replica_deaths"] == 1 and s["replicas_live"] == 2
        assert rs.replicas[0] is not built[0]
        # a stale double-notification from the dead worker is ignored
        built[0].die()
        assert rs.stats().data["replica_deaths"] == 1
    finally:
        rs.close()


def test_respawn_budget_bounds_flapping(spec):
    calls = {"n": 0}

    def factory(slot, on_death):
        calls["n"] += 1
        return FakeWorker(slot, on_death, delay=0.0)

    rs = ReplicaSet(spec, factory, 1, max_respawns=2, respawn_backoff=0.01)
    try:
        for _ in range(5):  # die more often than the budget allows
            w = rs.replicas[0]
            w.die()
            deadline = time.time() + 5
            while rs.replicas[0] is w and time.time() < deadline:
                time.sleep(0.01)
        # 1 initial build + at most max_respawns rebuilds
        assert calls["n"] <= 3
        assert rs.stats().data["replica_respawns"] <= 2
    finally:
        rs.close()


def test_doc_stats_is_hedged_too(spec):
    rs, built = make_set(spec, [{"delay": None}, {"delay": 0.0}],
                         hedge_ms=10.0)
    try:
        slot, kws = rs.doc_stats([1, 2]).result(timeout=10)
        assert slot == 1 and kws == ("1", "2")
        assert rs.stats().data["hedges_fired"] == 1
    finally:
        rs.close()


def test_replica_set_validates_n(spec):
    with pytest.raises(ValueError, match="replica"):
        ReplicaSet(spec, lambda s, d: None, 0)


# --------------------------------------------------------------------------- #
# Process-transport integration
# --------------------------------------------------------------------------- #


def _expected(corpus, q):
    return KeywordSearchEngine(corpus).query(q, backend="scalar")


def test_process_replicas_kill_one_is_invisible(corpus):
    """SIGKILL one replica mid-stream: zero client-visible errors, the
    query stream stays byte-identical, and the slot respawns."""
    want = _expected(corpus, "vinyl reissue")
    with ClusterService.from_tree(
        corpus, 2, transport="process", replicas=2, batch_window_ms=0.5,
    ) as svc:
        assert svc.pool.locality == ["replicas", "replicas"]
        for i in range(30):
            if i == 5:
                rs = svc.pool.workers[0]
                os.kill(rs.replicas[0]._proc.pid, signal.SIGKILL)
            got = svc.query("vinyl reissue", timeout=60)
            np.testing.assert_array_equal(got, want, err_msg=f"iter {i}")
        s = svc.stats().data
        assert s["replica_deaths"] >= 1
        # the dead slot comes back within the respawn window
        deadline = time.time() + 60
        while svc.stats().data.get("replicas_live", 0) < 4:
            assert time.time() < deadline, svc.stats().data
            time.sleep(0.25)


def test_process_replicas_hedge_masks_stall(corpus):
    """SIGSTOP one replica of each shard: the hedge fires and bounds the
    tail — queries complete fast instead of inheriting the stall."""
    want = _expected(corpus, "vinyl reissue")
    with ClusterService.from_tree(
        corpus, 2, transport="process", replicas=2,
        hedge_ms=25.0, batch_window_ms=0.5,
    ) as svc:
        for _ in range(3):
            svc.query("vinyl reissue", timeout=60)  # warm all replicas
        stalled = []
        try:
            for rs in svc.pool.workers:
                pid = rs.replicas[0]._proc.pid
                os.kill(pid, signal.SIGSTOP)
                stalled.append(pid)
            lat = []
            for i in range(10):
                t0 = time.perf_counter()
                got = svc.query("vinyl reissue", timeout=60)
                lat.append((time.perf_counter() - t0) * 1e3)
                np.testing.assert_array_equal(got, want, err_msg=f"iter {i}")
            # every query must finish in hedge-delay territory, nowhere
            # near a stall-length timeout
            assert max(lat) < 5_000, lat
            s = svc.stats().data
            assert s["hedges_fired"] >= 1 and s["hedge_wins"] >= 1
        finally:
            for pid in stalled:
                os.kill(pid, signal.SIGCONT)
