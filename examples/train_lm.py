"""End-to-end training driver: train a reduced SmolLM for a few hundred steps
with checkpointing and a simulated mid-run crash + automatic recovery.

    PYTHONPATH=src python examples/train_lm.py
"""
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, global_batch
from repro.models import init_params
from repro.train.fault import run_supervised
from repro.train.train_step import make_train_step

STEPS = int(os.environ.get("TRAIN_STEPS", "200"))
CRASH_AT = STEPS // 2


def main():
    cfg = get_config("smollm-135m").reduced()
    pipe = PipelineConfig(vocab=cfg.vocab, seq_len=128, global_batch=8, seed=0)
    init_state, train_step = make_train_step(
        cfg, optimizer="adamw", base_lr=3e-3, warmup=20, total_steps=STEPS
    )
    ckpt_dir = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    crashed = {"done": False}
    losses = []

    def make_step():
        jitted = jax.jit(train_step, donate_argnums=(0,))

        def step(state, batch):
            step_no = int(state["step"])
            if step_no == CRASH_AT and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("simulated host preemption")
            return jitted(state, batch)

        return step

    report = run_supervised(
        total_steps=STEPS,
        make_step=make_step,
        init_state=lambda: init_state(init_params(jax.random.key(0), cfg)),
        next_batch=lambda s: {"tokens": jnp.asarray(global_batch(pipe, s)["tokens"])},
        ckpt_dir=ckpt_dir,
        checkpoint_every=25,
        on_metrics=lambda s, m: (
            losses.append(float(m["loss"])),
            print(f"step {s:4d} loss {float(m['loss']):.4f}", flush=True)
            if s % 20 == 0 else None,
        ),
    )
    print(
        f"\nfinished {report.final_step} steps; "
        f"recovered from {report.failures_recovered} failure(s) "
        f"(simulated crash at step {CRASH_AT})"
    )
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert report.failures_recovered >= 1, "the simulated crash must be recovered"
    assert losses[-1] < losses[0], "training must make progress"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("OK")


if __name__ == "__main__":
    main()
