"""Batched serving with prefix-DAG KV dedup (the paper's insight on LMs).

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(
        main(
            [
                "--arch", "smollm-135m", "--reduced",
                "--requests", "8", "--prompt-len", "64",
                "--shared-prefix", "48", "--gen", "12", "--prefix-dag",
            ]
        )
    )
