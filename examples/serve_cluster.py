"""Build, publish, and serve a sharded cluster over the synthetic catalog.

    python examples/serve_cluster.py [n_releases] [num_shards] [transport]

Walks the full production path: partition the corpus into per-shard DAG
indices, publish them as a cluster artifact (atomic manifest swap), reopen
the artifact through the chosen worker transport — ``thread`` (in-process
engines), ``process`` (one subprocess per shard over the mmap'd artifact),
or ``remote`` (standalone shard servers on localhost sockets, their
endpoints recorded in ``cluster.json`` exactly as a multi-host deployment
would) — scatter-gather queries through admission control, then perform a
rolling republish against the live service (remote shards hot-swap through
the server's ``reload`` op) and print the rolled-up cluster stats.
"""
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.cluster import (  # noqa: E402
    ClusterService,
    build_cluster,
    rolling_publish,
    set_cluster_endpoints,
)
from repro.cluster.workers.server import launch_cluster_servers  # noqa: E402
from repro.core import KeywordSearchEngine  # noqa: E402
from repro.data import QUERIES, generate_discogs_tree  # noqa: E402


def main() -> None:
    n_releases = int(sys.argv[1]) if len(sys.argv) > 1 else 200
    num_shards = int(sys.argv[2]) if len(sys.argv) > 2 else 4
    transport = sys.argv[3] if len(sys.argv) > 3 else "thread"

    print(f"generating catalog: {n_releases} releases ...")
    tree = generate_discogs_tree(n_releases=n_releases, seed=0)

    with tempfile.TemporaryDirectory() as path:
        manifest = build_cluster(tree, num_shards, path)
        print(
            f"published cluster: {manifest['num_shards']} shards, "
            f"{manifest['num_docs']} docs, {manifest['num_nodes']} nodes -> {path}"
        )

        servers = []
        if transport == "remote":
            # one standalone shard server per shard (here all on localhost;
            # in production each runs on its shard's host).  Recording the
            # endpoints in cluster.json is all the router needs — from_dir
            # picks them up without an endpoints argument.
            servers, endpoints = launch_cluster_servers(
                path, manifest, batch_window_ms=2.0
            )
            for i, ep in enumerate(endpoints):
                print(f"  shard {i} server listening at {ep}")
            set_cluster_endpoints(path, endpoints)

        mono = KeywordSearchEngine(tree)  # equivalence witness
        try:
            _serve(path, transport, mono, tree)
        finally:
            for proc in servers:
                proc.terminate()


def _serve(path: str, transport: str, mono, tree) -> None:
    with ClusterService.from_dir(
        path, transport=transport, batch_window_ms=2.0
    ) as svc:
        print(f"serving via {transport} workers ({svc.pool.locality})")
        for name, (_cat, kws) in QUERIES.items():
            for sem in ("slca", "elca"):
                got = svc.query(kws, semantics=sem)
                want = mono.query(kws, semantics=sem, backend="scalar")
                tag = "==" if np.array_equal(got, want) else "!!"
                print(f"  {name} {sem:4s} {tag} {got.size} results")
        # a hot-query burst: identical in-flight queries coalesce into
        # one scatter-gather execution (see `coalesced` in the stats)
        futs = [svc.submit(QUERIES["Q4"][1]) for _ in range(20)]
        for f in futs:
            f.result()
        # rolling republish against the live service: every shard is
        # re-indexed and hot-swapped, generations bump, zero queries drop
        # (remote shards reload through their server's `reload` op)
        m = rolling_publish(path, tree, service=svc)
        gens = [s["generation"] for s in m["shards"]]
        got = svc.query(QUERIES["Q4"][1])
        want = mono.query(QUERIES["Q4"][1], backend="scalar")
        tag = "==" if np.array_equal(got, want) else "!!"
        print(f"\nrolling republish: generations={gens}, post-swap {tag}")
        print("\ncluster stats:")
        for key, val in sorted(svc.stats().summary().items()):
            print(f"  {key}: {val}")


if __name__ == "__main__":
    main()
