"""The full front door: replicated shards + hedged dispatch + HTTP gateway.

    python examples/serve_gateway.py [n_releases] [num_shards] [replicas]

Publishes the synthetic catalog as a cluster artifact, serves it through
per-shard replica sets (process transport), and puts the HTTP/JSON
gateway in front.  Then exercises everything a deployment cares about,
over real HTTP:

  * POST /query — ids byte-identical to a monolithic engine;
  * the edge cache — a repeated query returns ``cached: true`` without
    touching the cluster;
  * SIGSTOP one replica — hedged dispatch keeps answering fast while the
    replica is stalled (the tail stays near the hedge delay);
  * SIGKILL one replica — queries fail over with zero client errors and
    the slot respawns;
  * a rolling republish — shard generations bump and the edge cache
    invalidates itself (the repeat recomputes, then re-caches).
"""
import http.client
import json
import os
import signal
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import Query  # noqa: E402
from repro.cluster import (  # noqa: E402
    ClusterService,
    build_cluster,
    rolling_publish,
)
from repro.core import KeywordSearchEngine  # noqa: E402
from repro.data import QUERIES, generate_discogs_tree  # noqa: E402
from repro.gateway import Gateway  # noqa: E402


def post_query(host: str, port: int, body: dict) -> dict:
    conn = http.client.HTTPConnection(host, port, timeout=120)
    try:
        conn.request("POST", "/query", body=json.dumps(body))
        resp = conn.getresponse()
        obj = json.loads(resp.read().decode())
        if resp.status != 200:
            raise RuntimeError(f"{resp.status}: {obj.get('error')}")
        return obj
    finally:
        conn.close()


def get(host: str, port: int, path: str) -> dict:
    conn = http.client.HTTPConnection(host, port, timeout=120)
    try:
        conn.request("GET", path)
        return json.loads(conn.getresponse().read().decode())
    finally:
        conn.close()


def main() -> None:
    n_releases = int(sys.argv[1]) if len(sys.argv) > 1 else 120
    num_shards = int(sys.argv[2]) if len(sys.argv) > 2 else 2
    replicas = int(sys.argv[3]) if len(sys.argv) > 3 else 2

    print(f"generating catalog: {n_releases} releases ...")
    tree = generate_discogs_tree(n_releases=n_releases, seed=0)
    mono = KeywordSearchEngine(tree)

    with tempfile.TemporaryDirectory() as path:
        build_cluster(tree, num_shards, path)
        svc = ClusterService.from_dir(
            path, transport="process", replicas=replicas,
            batch_window_ms=1.0,
        )
        with Gateway(svc, own_service=True).start() as gw:
            print(
                f"gateway at http://{gw.endpoint} over {num_shards} shards "
                f"x {replicas} replicas ({svc.pool.locality})"
            )
            print(f"  try: curl -s {gw.endpoint}/query "
                  "-d '{\"keywords\": \"vinyl reissue\"}'")

            # 1. exactness over HTTP
            for name, (_cat, kws) in list(QUERIES.items())[:4]:
                obj = post_query(gw.host, gw.port, {"keywords": kws})
                want = mono.query(kws, backend="scalar")
                tag = "==" if np.array_equal(
                    np.asarray(obj["ids"], dtype=np.int64), want
                ) else "!!"
                print(f"  {name} slca {tag} {len(obj['ids'])} results "
                      f"({obj['stats']['latency_ms']}ms)")

            # 2. edge cache
            body = Query.make("vinyl reissue").to_dict()
            a = post_query(gw.host, gw.port, body)
            b = post_query(gw.host, gw.port, body)
            print(f"\nedge cache: first cached={a['cached']}, "
                  f"repeat cached={b['cached']}")

            # 3. hedging over a stalled replica
            rs = svc.pool.workers[0]
            pid = rs.replicas[0]._proc.pid
            os.kill(pid, signal.SIGSTOP)
            t0 = time.perf_counter()
            post_query(gw.host, gw.port, {"keywords": "limited vinyl"})
            stalled_ms = (time.perf_counter() - t0) * 1e3
            os.kill(pid, signal.SIGCONT)
            s = svc.stats().data
            print(f"stalled replica: answered in {stalled_ms:.0f}ms "
                  f"(hedges_fired={s.get('hedges_fired', 0)})")

            # 4. kill a replica mid-traffic: failover, then respawn
            os.kill(pid, signal.SIGKILL)
            errors = 0
            for _ in range(10):
                try:
                    post_query(gw.host, gw.port, {"keywords": "japan cd"})
                except RuntimeError:
                    errors += 1
            print(f"killed replica: {errors} client-visible errors in 10 "
                  "queries (failover)")

            # 5. rolling republish invalidates the cache
            rolling_publish(path, tree, service=svc)
            c = post_query(gw.host, gw.port, body)
            d = post_query(gw.host, gw.port, body)
            health = get(gw.host, gw.port, "/healthz")
            print(f"rolling republish: generations={health['generations']}, "
                  f"repeat cached={c['cached']} -> re-cached={d['cached']}")

            stats = get(gw.host, gw.port, "/stats")
            print("\ngateway counters:", stats["gateway"])


if __name__ == "__main__":
    main()
