"""End-to-end search driver: build the IDCluster over a discogs-like catalog
and run the paper's nine queries on base vs DAG indices with timings.

    PYTHONPATH=src python examples/search_discogs.py --releases 2000
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import KeywordSearchEngine
from repro.data import QUERIES, generate_discogs_tree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--releases", type=int, default=2000)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--semantics", default="slca", choices=["slca", "elca"])
    args = ap.parse_args()

    t0 = time.time()
    tree = generate_discogs_tree(n_releases=args.releases, seed=0)
    print(f"corpus: {tree.num_nodes} nodes ({time.time()-t0:.1f}s)")
    t0 = time.time()
    eng = KeywordSearchEngine(tree)
    s = eng.index_sizes()
    print(
        f"index: {s['tree_entries']} tree entries -> {s['dag_entries']} DAG entries "
        f"({s['num_rcs']} RCs, {time.time()-t0:.1f}s build)"
    )

    print(f"\n{'query':34s} {'cat':>3s} {'results':>8s} {'base µs':>10s} "
          f"{'DAG µs':>10s} {'speedup':>8s}")
    for q, (cat, kws) in QUERIES.items():
        res = eng.query(kws, semantics=args.semantics, index="tree")
        dag_res = eng.query(kws, semantics=args.semantics, index="dag")
        assert np.array_equal(res, dag_res), "DAG results must match tree results"

        def bench(index):
            eng.query(kws, semantics=args.semantics, index=index)
            t = time.time()
            for _ in range(args.repeats):
                eng.query(kws, semantics=args.semantics, index=index)
            return (time.time() - t) / args.repeats * 1e6

        b, d = bench("tree"), bench("dag")
        print(f"{q} {' '.join(kws):27s} {cat:3d} {len(res):8d} "
              f"{b:10.0f} {d:10.0f} {b/d:7.2f}x")


if __name__ == "__main__":
    main()
