"""Index-artifact workflow end to end: build -> save -> reload -> serve.

    PYTHONPATH=src python examples/serve_index.py [--releases 200]

Builds the synthetic discogs corpus, saves the index artifact, reloads it
the way a serving process would (memory-mapped, no rebuild), then serves
the paper's 9 queries twice through a QueryService — the second pass shows
the PlanCache serving every launch from warm executables.
"""
import argparse
import tempfile
import time

from repro.core import KeywordSearchEngine
from repro.data import QUERIES, generate_discogs_tree
from repro.serve import QueryService


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--releases", type=int, default=200)
    ap.add_argument("--artifact", default=None, help="default: a temp dir")
    args = ap.parse_args()

    artifact = args.artifact or tempfile.mkdtemp(prefix="idx-")

    t0 = time.perf_counter()
    tree = generate_discogs_tree(n_releases=args.releases, seed=0)
    engine = KeywordSearchEngine(tree)
    print(f"built {tree.num_nodes} nodes in {time.perf_counter() - t0:.2f}s")
    print(f"index sizes: {engine.index_sizes()}")

    t0 = time.perf_counter()
    engine.save(artifact)
    print(f"saved artifact -> {artifact} in {time.perf_counter() - t0:.2f}s")

    t0 = time.perf_counter()
    served = KeywordSearchEngine.load(artifact)  # mmap: no rebuild
    print(f"reloaded (mmap) in {time.perf_counter() - t0:.3f}s")

    queries = [kws for _, kws in QUERIES.values()]
    with QueryService(served, max_batch=32, batch_window_ms=2.0) as svc:
        for label in ("cold", "warm"):
            t0 = time.perf_counter()
            results = svc.map(queries, semantics="slca")
            dt = (time.perf_counter() - t0) * 1e3
            hits = svc.stats().data["plan_hit_rate"]
            print(
                f"{label}: {len(results)} queries in {dt:.1f}ms, "
                f"plan hit-rate {hits:.2f}"
            )
        for (name, (_, kws)), res in zip(QUERIES.items(), results):
            print(f"  {name} {kws} -> {len(res)} results")
        print("service stats:", svc.stats().summary())


if __name__ == "__main__":
    main()
