"""Quickstart: DAG-compressed XML keyword search in five lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import KeywordSearchEngine

XML = """
<bib>
  <release>
    <title>Thriller</title>
    <versions>
      <details><format>Vinyl</format><country>USA</country><language>English</language></details>
    </versions>
    <note>USA</note><note2>English</note2>
  </release>
  <release2>
    <details><format>Vinyl</format><country>USA</country><language>English</language></details>
  </release2>
</bib>
"""

engine = KeywordSearchEngine.from_xml(XML)

print("query: USA English")
for semantics in ("slca", "elca"):
    for index in ("tree", "dag"):
        for backend in ("scalar", "jax", "pallas"):
            ids = engine.query(["USA", "English"], semantics=semantics,
                               index=index, backend=backend)
            print(f"  {semantics:4s} {index:4s} {backend:6s} -> nodes {ids.tolist()}")

sizes = engine.index_sizes()
print(f"tree nodes: {sizes['tree_nodes']}, DAG nodes: {sizes['dag_nodes']}, "
      f"redundancy components: {sizes['num_rcs']}, RCPM entries: {sizes['rcpm_entries']}")
